"""The three registered kernel backends: ``pallas`` | ``jnp`` | ``ref``.

Each backend exposes the same user-shape API (DESIGN.md §4):

* ``query_eval(leaf_lo, leaf_hi, leaf_agg, q_lo, q_hi)``
    -> (rel (Q, k) int32, exact (Q, A) f32)
  classifies every leaf against every query AND accumulates the exact
  covered-aggregate sums in the same pass (the MXU matmul of the Pallas
  kernel; the engine consumes ``exact`` instead of recomputing it).
* ``stratified_moments(sample_c, sample_a, sample_valid, q_lo, q_hi)``
    -> (k_pred, s_sum, s_sumsq), each (Q, k) f32
  per-(query, stratum) relevant-sample moments over the synopsis-shaped
  (k, s, ·) sample arrays.
* ``stratified_moments_flat(...)`` — the flattened (S, ·) calling
  convention kept for the public ``ops.py`` wrappers.
* ``segment_reduce(values, seg_ids, k)`` -> (k, 5) per-segment aggregates.
* ``sample_extremes(...)`` -> per-(query, stratum) relevant-sample MIN/MAX
  (shared broadcast implementation — no Pallas kernel exists for it yet).

``pallas`` runs the TPU kernels (interpret mode off-TPU), ``ref`` runs the
kernel-convention oracles of ``ref.py`` through the identical padding
adapters, and ``jnp`` is the broadcast formulation that is fastest on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref as _ref
from .registry import register_backend
from .bootstrap import (bootstrap_moments as _boot_pallas, auto_block_r)
from .route import (route_multid_dense as _route_dense,
                    route_multid_pallas as _route_pallas,
                    auto_block_k)
from .segment_reduce import (segment_reduce as _segment_reduce_pallas,
                             weighted_segment_reduce as _wseg_pallas,
                             auto_block_n)
from .stratified_estimate import (stratified_moments as _strat_pallas,
                                  stratified_weighted_moments as _wstrat_pallas)
from .query_eval import query_eval as _query_eval_pallas

D_PAD = 8

# Relation codes — must match core.types (kernels stay import-free of core).
REL_NONE, REL_PARTIAL, REL_COVER = 0, 1, 2

_BIG = jnp.float32(3.4e38)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x: jnp.ndarray, mult: int, axis: int, fill=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def _transpose_coords(c: jnp.ndarray) -> jnp.ndarray:
    """(N, d) -> (D_PAD, N) with padded dims filled so they never filter."""
    c_t = jnp.swapaxes(c, 0, 1)
    return _pad_axis(c_t, D_PAD, 0, fill=0.0)


# --------------------------------------------------------------------------
# Pure-jnp broadcast formulations (also the semantic references for the
# kernels; re-exported by core.estimators for compatibility)
# --------------------------------------------------------------------------

def classify_leaves(leaf_lo, leaf_hi, q_lo, q_hi):
    """(k,d) boxes vs (Q,d) rectangles -> (Q,k) int32 relation codes."""
    nonempty = jnp.all(leaf_lo <= leaf_hi, axis=-1)          # (k,)
    ql = q_lo[:, None, :]                                    # (Q,1,d)
    qh = q_hi[:, None, :]
    disjoint = (jnp.any(qh < leaf_lo[None], axis=-1)
                | jnp.any(ql > leaf_hi[None], axis=-1)
                | ~nonempty[None])
    cover = (jnp.all(ql <= leaf_lo[None], axis=-1)
             & jnp.all(leaf_hi[None] <= qh, axis=-1)
             & nonempty[None])
    return jnp.where(cover, REL_COVER,
                     jnp.where(disjoint, REL_NONE, REL_PARTIAL)).astype(jnp.int32)


def sample_moments(sample_c, sample_a, sample_valid, q_lo, q_hi):
    """Per-(query, stratum) relevant-sample moments.

    Returns (k_pred, s_sum, s_sumsq), each (Q, k) f32. Pure-jnp reference
    semantics for the `stratified_estimate` Pallas kernel.
    """
    # pred: (Q, k, s)
    inside = (jnp.all(q_lo[:, None, None, :] <= sample_c[None], axis=-1)
              & jnp.all(sample_c[None] <= q_hi[:, None, None, :], axis=-1))
    pred = (inside & sample_valid[None]).astype(jnp.float32)
    a = sample_a.astype(jnp.float32)[None]
    k_pred = jnp.sum(pred, axis=-1)
    s_sum = jnp.sum(pred * a, axis=-1)
    s_sumsq = jnp.sum(pred * a * a, axis=-1)
    return k_pred, s_sum, s_sumsq


def tree_sum_last(x: jnp.ndarray) -> jnp.ndarray:
    """Bit-deterministic pairwise reduction over the trailing axis.

    ``jnp.sum`` leaves the accumulation strategy to the XLA reduce
    emitter, which picks different vectorizations in different fusion
    contexts — two programs summing identical values can disagree in the
    last ulp. This fixed-structure binary tree of *elementwise* adds pins
    the accumulation order in the graph itself (elementwise ops are
    bit-deterministic regardless of surrounding fusion), which is what the
    fused-vs-scan bootstrap bit-identity contract (DESIGN.md §10) rests
    on. Same flops as a linear sum; zero-padding to the next power of two
    is exact (x + 0.0 == x in f32 for all finite x)."""
    n = x.shape[-1]
    pow2 = 1 << max(n - 1, 0).bit_length()
    if pow2 != n:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pow2 - n)]
        x = jnp.pad(x, widths)
    while x.shape[-1] > 1:
        h = x.shape[-1] // 2
        x = x[..., :h] + x[..., h:]          # contiguous halves: SIMD-friendly
    return x[..., 0]


def weighted_sample_moments(sample_c, sample_a, sample_valid, weights,
                            q_lo, q_hi):
    """Per-(query, stratum) weighted relevant-sample moments.

    ``weights`` (k, s) f32 resample weights (the uncertainty subsystem's
    Poisson bootstrap); invalid slots are masked regardless of weight.
    Returns (w_pred, ws_sum, ws_sumsq), each (Q, k) f32. The slot
    reduction is the fixed-order :func:`tree_sum_last`, so one replicate
    computed here bit-matches the same replicate inside the fused
    ``bootstrap_moments`` block."""
    inside = (jnp.all(q_lo[:, None, None, :] <= sample_c[None], axis=-1)
              & jnp.all(sample_c[None] <= q_hi[:, None, None, :], axis=-1))
    pred = (inside & sample_valid[None]).astype(jnp.float32)
    pred = pred * weights.astype(jnp.float32)[None]
    a = sample_a.astype(jnp.float32)[None]
    w_pred = tree_sum_last(pred)
    ws_sum = tree_sum_last(pred * a)
    ws_sumsq = tree_sum_last(pred * a * a)
    return w_pred, ws_sum, ws_sumsq


def _flat_leaf_ids(sample_valid: jnp.ndarray) -> jnp.ndarray:
    k, s = sample_valid.shape
    return jnp.where(sample_valid.reshape(k * s),
                     jnp.repeat(jnp.arange(k, dtype=jnp.int32), s), -1)


# --------------------------------------------------------------------------
# Backend classes
# --------------------------------------------------------------------------

class KernelBackend:
    """Uniform op surface; subclasses fill in the hot paths."""

    name = "base"

    # -- classification + exact accumulation --------------------------------
    def query_eval(self, leaf_lo, leaf_hi, leaf_agg, q_lo, q_hi,
                   bq: int = 128, bk: int = 128):
        raise NotImplementedError

    # -- stratified moments --------------------------------------------------
    def stratified_moments(self, sample_c, sample_a, sample_valid,
                           q_lo, q_hi, **kw):
        k, s, d = sample_c.shape
        mom = self.stratified_moments_flat(
            sample_c.reshape(k * s, d), sample_a.reshape(k * s),
            _flat_leaf_ids(sample_valid), q_lo, q_hi, k, **kw)
        return mom[..., 0], mom[..., 1], mom[..., 2]

    def stratified_moments_flat(self, sample_c, sample_a, sample_leaf,
                                q_lo, q_hi, k: int, bq: int = 128,
                                bk: int = 128, bs: int = 1024):
        raise NotImplementedError

    # -- weighted stratified moments (uncertainty / bootstrap path) ----------
    def weighted_moments(self, sample_c, sample_a, sample_valid, weights,
                         q_lo, q_hi, **kw):
        k, s, d = sample_c.shape
        w = jnp.where(sample_valid, weights.astype(jnp.float32), 0.0)
        mom = self.weighted_moments_flat(
            sample_c.reshape(k * s, d), sample_a.reshape(k * s),
            _flat_leaf_ids(sample_valid), w.reshape(k * s), q_lo, q_hi, k,
            **kw)
        return mom[..., 0], mom[..., 1], mom[..., 2]

    def weighted_moments_flat(self, sample_c, sample_a, sample_leaf, weights,
                              q_lo, q_hi, k: int, bq: int = 128,
                              bk: int = 128, bs: int = 1024):
        raise NotImplementedError

    # -- fused bootstrap replicate moments (DESIGN.md §10) -------------------
    # One op for the whole (R, Q, k, 3) replicate-moment block; the default
    # is the per-replicate oracle loop (structurally bit-identical to the
    # scan path), which `pallas`/`jnp` replace with genuinely fused
    # formulations. ``br=None`` auto-sizes the replicate block.
    def bootstrap_moments(self, sample_c, sample_a, sample_valid, weights,
                          q_lo, q_hi, **kw):
        """``weights`` (R, k, s) resample weights -> (R, Q, k, 3) f32
        [sum w*pred, sum w*pred*a, sum w*pred*a^2] per replicate."""
        k, s, d = sample_c.shape
        R = weights.shape[0]
        w = jnp.where(sample_valid[None], weights.astype(jnp.float32), 0.0)
        return self.bootstrap_moments_flat(
            sample_c.reshape(k * s, d), sample_a.reshape(k * s),
            _flat_leaf_ids(sample_valid), w.reshape(R, k * s),
            q_lo, q_hi, k, **kw)

    def bootstrap_moments_flat(self, sample_c, sample_a, sample_leaf,
                               weights, q_lo, q_hi, k: int,
                               br: int | None = None, bq: int = 128,
                               bk: int = 128, bs: int = 1024):
        # Oracle default: the scan path's per-replicate op, stacked.
        return jnp.stack([
            self.weighted_moments_flat(sample_c, sample_a, sample_leaf,
                                       weights[r], q_lo, q_hi, k,
                                       bq=bq, bk=bk, bs=bs)
            for r in range(weights.shape[0])])

    # -- multi-D batch routing (streaming ingest hot path) -------------------
    def route_multid(self, leaf_lo, leaf_hi, c, bk: int | None = None):
        """Nearest-leaf routing for (B, d) rows against (k, d) boxes.
        Returns (leaf ids (B,) int32, selected L1 distance (B,) f32).
        Default: the dense (B, k) distance-matrix oracle."""
        return _route_dense(leaf_lo, leaf_hi, c)

    # -- segment reduction ---------------------------------------------------
    # ``bn=None`` sizes the row block to the input (auto_block_n) — the
    # streaming ingest path reduces small batches where the build-path
    # default of 2048 would pad 2-4x.
    def segment_reduce(self, values, seg_ids, k: int, bn: int | None = 2048,
                       bk: int = 256):
        bn = bn or auto_block_n(values.shape[0])
        v = _pad_axis(values.astype(jnp.float32), bn, 0)
        ids = _pad_axis(seg_ids.astype(jnp.int32), bn, 0, fill=-1)
        return _ref.segment_reduce_ref(v, ids, k)[:, :5]

    def weighted_segment_reduce(self, values, weights, seg_ids, k: int,
                                bn: int | None = 2048, bk: int = 256):
        """Per-segment [sum w*v, sum w*v^2, sum w]. Returns (k, 3)."""
        bn = bn or auto_block_n(values.shape[0])
        v = _pad_axis(values.astype(jnp.float32), bn, 0)
        w = _pad_axis(weights.astype(jnp.float32), bn, 0)
        ids = _pad_axis(seg_ids.astype(jnp.int32), bn, 0, fill=-1)
        return _ref.weighted_segment_reduce_ref(v, w, ids, k)

    # -- relevant-sample extremes (shared broadcast implementation) ----------
    def sample_extremes(self, sample_c, sample_a, sample_valid, q_lo, q_hi):
        """Per-(query, stratum) MIN/MAX over relevant samples; irrelevant
        strata read +BIG / -BIG. Returns (samp_min, samp_max), each (Q, k)."""
        inside = (jnp.all(q_lo[:, None, None, :] <= sample_c[None], axis=-1)
                  & jnp.all(sample_c[None] <= q_hi[:, None, None, :], axis=-1)
                  & sample_valid[None])
        a = sample_a.astype(jnp.float32)[None]
        samp_min = jnp.min(jnp.where(inside, a, _BIG), axis=-1)
        samp_max = jnp.max(jnp.where(inside, a, -_BIG), axis=-1)
        return samp_min, samp_max


def _pad_query_eval_inputs(leaf_lo, leaf_hi, leaf_agg, q_lo, q_hi, bq, bk):
    # Empty-leaf boxes (lo > hi) must stay inverted after padding.
    lo_t = _pad_axis(_transpose_coords(leaf_lo.astype(jnp.float32)), bk, 1,
                     fill=1.0)
    hi_t = _pad_axis(_transpose_coords(leaf_hi.astype(jnp.float32)), bk, 1,
                     fill=-1.0)
    agg = _pad_axis(_pad_axis(leaf_agg.astype(jnp.float32), 8, 1), bk, 0)
    qlo_t = _pad_axis(_transpose_coords(q_lo.astype(jnp.float32)), bq, 1,
                      fill=1.0)
    qhi_t = _pad_axis(_transpose_coords(q_hi.astype(jnp.float32)), bq, 1,
                      fill=-1.0)
    return lo_t, hi_t, agg, qlo_t, qhi_t


def _pad_moment_inputs(sample_c, sample_a, sample_leaf, q_lo, q_hi, bq, bs):
    c_t = _pad_axis(_transpose_coords(sample_c.astype(jnp.float32)), bs, 1)
    a = _pad_axis(sample_a.astype(jnp.float32), bs, 0)
    leaf = _pad_axis(sample_leaf.astype(jnp.int32), bs, 0, fill=-1)
    qlo_t = _pad_axis(_transpose_coords(q_lo.astype(jnp.float32)), bq, 1,
                      fill=1.0)
    qhi_t = _pad_axis(_transpose_coords(q_hi.astype(jnp.float32)), bq, 1,
                      fill=-1.0)
    return c_t, a, leaf, qlo_t, qhi_t


@register_backend("pallas")
class PallasBackend(KernelBackend):
    """Pallas TPU kernels (compiled on TPU, interpret mode elsewhere)."""

    def query_eval(self, leaf_lo, leaf_hi, leaf_agg, q_lo, q_hi,
                   bq: int = 128, bk: int = 128):
        k, d = leaf_lo.shape
        Q, A = q_lo.shape[0], leaf_agg.shape[1]
        lo_t, hi_t, agg, qlo_t, qhi_t = _pad_query_eval_inputs(
            leaf_lo, leaf_hi, leaf_agg, q_lo, q_hi, bq, bk)
        rel, exact = _query_eval_pallas(lo_t, hi_t, agg, qlo_t, qhi_t, d,
                                        bq=bq, bk=bk, interpret=_interpret())
        return rel[:Q, :k], exact[:Q, :A]

    def stratified_moments_flat(self, sample_c, sample_a, sample_leaf,
                                q_lo, q_hi, k: int, bq: int = 128,
                                bk: int = 128, bs: int = 1024):
        d = sample_c.shape[1]
        Q = q_lo.shape[0]
        c_t, a, leaf, qlo_t, qhi_t = _pad_moment_inputs(
            sample_c, sample_a, sample_leaf, q_lo, q_hi, bq, bs)
        k_pad = k + ((-k) % bk)
        out = _strat_pallas(c_t, a, leaf, qlo_t, qhi_t, k_pad, d,
                            bq=bq, bk=bk, bs=bs, interpret=_interpret())
        return out[:Q, :k]

    def weighted_moments_flat(self, sample_c, sample_a, sample_leaf, weights,
                              q_lo, q_hi, k: int, bq: int = 128,
                              bk: int = 128, bs: int = 1024):
        d = sample_c.shape[1]
        Q = q_lo.shape[0]
        c_t, a, leaf, qlo_t, qhi_t = _pad_moment_inputs(
            sample_c, sample_a, sample_leaf, q_lo, q_hi, bq, bs)
        w = _pad_axis(weights.astype(jnp.float32), bs, 0)
        k_pad = k + ((-k) % bk)
        out = _wstrat_pallas(c_t, a, leaf, w, qlo_t, qhi_t, k_pad, d,
                             bq=bq, bk=bk, bs=bs, interpret=_interpret())
        return out[:Q, :k]

    def bootstrap_moments_flat(self, sample_c, sample_a, sample_leaf,
                               weights, q_lo, q_hi, k: int,
                               br: int | None = None, bq: int = 128,
                               bk: int = 128, bs: int = 1024):
        d = sample_c.shape[1]
        R = weights.shape[0]
        Q = q_lo.shape[0]
        br = br or auto_block_r(R)
        c_t, a, leaf, qlo_t, qhi_t = _pad_moment_inputs(
            sample_c, sample_a, sample_leaf, q_lo, q_hi, bq, bs)
        w = _pad_axis(_pad_axis(weights.astype(jnp.float32), bs, 1), br, 0)
        k_pad = k + ((-k) % bk)
        out = _boot_pallas(c_t, a, leaf, w, qlo_t, qhi_t, k_pad, d,
                           br=br, bq=bq, bk=bk, bs=bs,
                           interpret=_interpret())
        return out[:R, :Q, :k]

    def route_multid(self, leaf_lo, leaf_hi, c, bk: int | None = None):
        b, d = c.shape
        k = leaf_lo.shape[0]
        bk = bk or auto_block_k(k)
        bb = 256 if b >= 256 else 8 * ((b + 7) // 8)
        # Padding strata are inverted ±BIG boxes: unreachable distance.
        lo_t = _pad_axis(_transpose_coords(leaf_lo.astype(jnp.float32)),
                         bk, 1, fill=_ref.POS_BIG)
        hi_t = _pad_axis(_transpose_coords(leaf_hi.astype(jnp.float32)),
                         bk, 1, fill=_ref.NEG_BIG)
        c_t = _pad_axis(_transpose_coords(c.astype(jnp.float32)), bb, 1)
        idx, dist = _route_pallas(lo_t, hi_t, c_t, d, bb=bb, bk=bk,
                                  interpret=_interpret())
        return idx[:b], dist[:b]

    def segment_reduce(self, values, seg_ids, k: int, bn: int | None = 2048,
                       bk: int = 256):
        bn = bn or auto_block_n(values.shape[0])
        v = _pad_axis(values.astype(jnp.float32), bn, 0)
        ids = _pad_axis(seg_ids.astype(jnp.int32), bn, 0, fill=-1)
        k_pad = k + ((-k) % bk)
        out = _segment_reduce_pallas(v, ids, k_pad, bn=bn, bk=bk,
                                     interpret=_interpret())
        return out[:k, :5]

    def weighted_segment_reduce(self, values, weights, seg_ids, k: int,
                                bn: int | None = 2048, bk: int = 256):
        bn = bn or auto_block_n(values.shape[0])
        v = _pad_axis(values.astype(jnp.float32), bn, 0)
        w = _pad_axis(weights.astype(jnp.float32), bn, 0)
        ids = _pad_axis(seg_ids.astype(jnp.int32), bn, 0, fill=-1)
        k_pad = k + ((-k) % bk)
        out = _wseg_pallas(v, w, ids, k_pad, bn=bn, bk=bk,
                           interpret=_interpret())
        return out[:k, :3]


@register_backend("ref")
class RefBackend(KernelBackend):
    """The ref.py oracles through the exact Pallas padding adapters —
    value-identical to ``pallas`` without the interpreter overhead."""

    def query_eval(self, leaf_lo, leaf_hi, leaf_agg, q_lo, q_hi,
                   bq: int = 128, bk: int = 128):
        k, d = leaf_lo.shape
        Q, A = q_lo.shape[0], leaf_agg.shape[1]
        lo_t, hi_t, agg, qlo_t, qhi_t = _pad_query_eval_inputs(
            leaf_lo, leaf_hi, leaf_agg, q_lo, q_hi, bq, bk)
        rel, exact = _ref.query_eval_ref(lo_t, hi_t, agg, qlo_t, qhi_t, d)
        return rel[:Q, :k], exact[:Q, :A]

    def stratified_moments_flat(self, sample_c, sample_a, sample_leaf,
                                q_lo, q_hi, k: int, bq: int = 128,
                                bk: int = 128, bs: int = 1024):
        d = sample_c.shape[1]
        Q = q_lo.shape[0]
        c_t, a, leaf, qlo_t, qhi_t = _pad_moment_inputs(
            sample_c, sample_a, sample_leaf, q_lo, q_hi, bq, bs)
        return _ref.stratified_moments_ref(c_t, a, leaf, qlo_t, qhi_t, k, d)[:Q]

    def weighted_moments_flat(self, sample_c, sample_a, sample_leaf, weights,
                              q_lo, q_hi, k: int, bq: int = 128,
                              bk: int = 128, bs: int = 1024):
        d = sample_c.shape[1]
        Q = q_lo.shape[0]
        c_t, a, leaf, qlo_t, qhi_t = _pad_moment_inputs(
            sample_c, sample_a, sample_leaf, q_lo, q_hi, bq, bs)
        w = _pad_axis(weights.astype(jnp.float32), bs, 0)
        return _ref.stratified_weighted_moments_ref(
            c_t, a, leaf, w, qlo_t, qhi_t, k, d)[:Q]


@register_backend("jnp")
class JnpBackend(KernelBackend):
    """Broadcast jnp formulation — the CPU-fast default off-TPU."""

    def query_eval(self, leaf_lo, leaf_hi, leaf_agg, q_lo, q_hi,
                   bq: int = 128, bk: int = 128):
        rel = classify_leaves(leaf_lo, leaf_hi, q_lo, q_hi)
        cover = (rel == REL_COVER).astype(jnp.float32)
        exact = cover @ leaf_agg.astype(jnp.float32)
        return rel, exact

    def stratified_moments(self, sample_c, sample_a, sample_valid,
                           q_lo, q_hi, **kw):
        return sample_moments(sample_c, sample_a, sample_valid, q_lo, q_hi)

    def weighted_moments(self, sample_c, sample_a, sample_valid, weights,
                         q_lo, q_hi, **kw):
        return weighted_sample_moments(sample_c, sample_a, sample_valid,
                                       weights, q_lo, q_hi)

    def bootstrap_moments(self, sample_c, sample_a, sample_valid, weights,
                          q_lo, q_hi, br: int | None = None, **kw):
        # Replicate-tiled broadcast-reduce: the predicate mask (the
        # w-independent half of `weighted_sample_moments`) is computed once
        # and reused by every replicate; a lax.scan walks (br, k, s) weight
        # tiles so the (br, Q, k, s) product is the largest temporary. The
        # per-replicate arithmetic (elementwise products + trailing-axis
        # sums) is exactly the scan path's, so replicates are bit-identical
        # to per-replicate `weighted_moments` calls.
        k, s, _ = sample_c.shape
        Q = q_lo.shape[0]
        R = weights.shape[0]
        br = br or auto_block_r(R)
        w = jnp.where(sample_valid[None], weights.astype(jnp.float32), 0.0)
        pad = (-R) % br
        if pad:
            w = jnp.concatenate(
                [w, jnp.zeros((pad, k, s), jnp.float32)], axis=0)
        inside = (jnp.all(q_lo[:, None, None, :] <= sample_c[None], axis=-1)
                  & jnp.all(sample_c[None] <= q_hi[:, None, None, :],
                            axis=-1))
        pred = (inside & sample_valid[None]).astype(jnp.float32)  # (Q,k,s)
        a = sample_a.astype(jnp.float32)[None, None]              # (1,1,k,s)

        def step(carry, wt):                                      # (br,k,s)
            p = pred[None] * wt[:, None]                          # (br,Q,k,s)
            return carry, jnp.stack(
                [tree_sum_last(p), tree_sum_last(p * a),
                 tree_sum_last(p * a * a)], axis=-1)

        _, out = jax.lax.scan(step, 0, w.reshape(-1, br, k, s))
        return out.reshape(-1, Q, k, 3)[:R]

    def weighted_segment_reduce(self, values, weights, seg_ids, k: int,
                                bn: int | None = 2048, bk: int = 256):
        # Scatter formulation, mirroring segment_reduce: O(N) work with a
        # spill slot for padding/out-of-range ids.
        v = values.astype(jnp.float32)
        w = weights.astype(jnp.float32)
        ids = jnp.where((seg_ids >= 0) & (seg_ids < k),
                        seg_ids.astype(jnp.int32), k)
        s = jnp.zeros(k + 1, jnp.float32).at[ids].add(w * v)
        ssq = jnp.zeros(k + 1, jnp.float32).at[ids].add(w * v * v)
        wsum = jnp.zeros(k + 1, jnp.float32).at[ids].add(w)
        return jnp.stack([s, ssq, wsum], axis=-1)[:k]

    def segment_reduce(self, values, seg_ids, k: int, bn: int | None = 2048,
                       bk: int = 256):
        # Scatter formulation: O(N) work instead of the O(N*k) one-hot
        # matmul — the right shape for CPU and for the streaming ingest
        # hot path, where N is a small row batch. Padding rows (-1) and
        # out-of-range ids drop into a spill slot that is sliced away.
        v = values.astype(jnp.float32)
        ids = jnp.where((seg_ids >= 0) & (seg_ids < k),
                        seg_ids.astype(jnp.int32), k)
        s = jnp.zeros(k + 1, jnp.float32).at[ids].add(v)
        ssq = jnp.zeros(k + 1, jnp.float32).at[ids].add(v * v)
        cnt = jnp.zeros(k + 1, jnp.float32).at[ids].add(1.0)
        vmin = jnp.full(k + 1, _ref.POS_BIG, jnp.float32).at[ids].min(v)
        vmax = jnp.full(k + 1, _ref.NEG_BIG, jnp.float32).at[ids].max(v)
        return jnp.stack([s, ssq, cnt, vmin, vmax], axis=-1)[:k]

    def stratified_moments_flat(self, sample_c, sample_a, sample_leaf,
                                q_lo, q_hi, k: int, bq: int = 128,
                                bk: int = 128, bs: int = 1024):
        pred = (jnp.all(q_lo[:, None, :] <= sample_c[None], axis=-1)
                & jnp.all(sample_c[None] <= q_hi[:, None, :], axis=-1)
                & (sample_leaf >= 0)[None])
        predf = pred.astype(jnp.float32)
        a = sample_a.astype(jnp.float32)
        onehot = (sample_leaf[:, None] == jnp.arange(k, dtype=jnp.int32)[None]
                  ).astype(jnp.float32)            # (S, k)
        kp = predf @ onehot
        sm = (predf * a[None]) @ onehot
        sq = (predf * (a * a)[None]) @ onehot
        return jnp.stack([kp, sm, sq], axis=-1)

    def weighted_moments_flat(self, sample_c, sample_a, sample_leaf, weights,
                              q_lo, q_hi, k: int, bq: int = 128,
                              bk: int = 128, bs: int = 1024):
        pred = (jnp.all(q_lo[:, None, :] <= sample_c[None], axis=-1)
                & jnp.all(sample_c[None] <= q_hi[:, None, :], axis=-1)
                & (sample_leaf >= 0)[None])
        predf = pred.astype(jnp.float32) * weights.astype(jnp.float32)[None]
        a = sample_a.astype(jnp.float32)
        onehot = (sample_leaf[:, None] == jnp.arange(k, dtype=jnp.int32)[None]
                  ).astype(jnp.float32)            # (S, k)
        kp = predf @ onehot
        sm = (predf * a[None]) @ onehot
        sq = (predf * (a * a)[None]) @ onehot
        return jnp.stack([kp, sm, sq], axis=-1)


__all__ = ["KernelBackend", "PallasBackend", "RefBackend", "JnpBackend",
           "classify_leaves", "sample_moments", "weighted_sample_moments",
           "D_PAD"]
