"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Shapes follow the kernel calling convention exactly (including the
transposed (d_pad, ·) coordinate layouts chosen for TPU lane alignment);
`ops.py` adapts user-facing shapes to these.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_BIG = -3.0e38
POS_BIG = 3.0e38


def segment_reduce_ref(values: jnp.ndarray, seg_ids: jnp.ndarray, k: int
                       ) -> jnp.ndarray:
    """Per-segment [sum, sumsq, count, min, max].

    values (N,) f32; seg_ids (N,) int32 in [0, k) or -1 for padding rows.
    Returns (k, 5) f32; empty segments get [0, 0, 0, +BIG, -BIG].
    """
    onehot = (seg_ids[:, None] == jnp.arange(k, dtype=jnp.int32)[None]
              ).astype(jnp.float32)
    s = onehot.T @ values
    ssq = onehot.T @ (values * values)
    cnt = onehot.sum(axis=0)
    vmin = jnp.min(jnp.where(onehot > 0, values[:, None], POS_BIG), axis=0)
    vmax = jnp.max(jnp.where(onehot > 0, values[:, None], NEG_BIG), axis=0)
    return jnp.stack([s, ssq, cnt, vmin, vmax], axis=-1)


def weighted_segment_reduce_ref(values: jnp.ndarray, weights: jnp.ndarray,
                                seg_ids: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per-segment weighted sums [sum w*v, sum w*v^2, sum w].

    values/weights (N,) f32; seg_ids (N,) int32 in [0, k) or -1 for padding
    (padding rows must carry weight 0). Returns (k, 3) f32.
    """
    onehot = (seg_ids[:, None] == jnp.arange(k, dtype=jnp.int32)[None]
              ).astype(jnp.float32)
    wv = weights * values
    s = onehot.T @ wv
    ssq = onehot.T @ (wv * values)
    wsum = onehot.T @ weights
    return jnp.stack([s, ssq, wsum], axis=-1)


def stratified_moments_ref(c_t: jnp.ndarray, a: jnp.ndarray,
                           leaf: jnp.ndarray, qlo_t: jnp.ndarray,
                           qhi_t: jnp.ndarray, k: int, d: int
                           ) -> jnp.ndarray:
    """Per-(query, stratum) relevant-sample moments [k_pred, sum, sumsq].

    c_t (d_pad, S) transposed sample coords; a (S,) values; leaf (S,) int32
    stratum id (-1 = padding); qlo_t/qhi_t (d_pad, Q). Only the first `d`
    coordinate rows participate. Returns (Q, k, 3) f32.
    """
    S = a.shape[0]
    Q = qlo_t.shape[1]
    pred = jnp.ones((Q, S), dtype=jnp.bool_)
    for j in range(d):
        cj = c_t[j][None, :]                    # (1,S)
        pred = pred & (qlo_t[j][:, None] <= cj) & (cj <= qhi_t[j][:, None])
    pred = pred & (leaf >= 0)[None, :]
    predf = pred.astype(jnp.float32)
    onehot = (leaf[:, None] == jnp.arange(k, dtype=jnp.int32)[None]
              ).astype(jnp.float32)              # (S,k)
    kp = predf @ onehot                          # (Q,k)
    sm = (predf * a[None]) @ onehot
    sq = (predf * (a * a)[None]) @ onehot
    return jnp.stack([kp, sm, sq], axis=-1)


def stratified_weighted_moments_ref(c_t: jnp.ndarray, a: jnp.ndarray,
                                    leaf: jnp.ndarray, w: jnp.ndarray,
                                    qlo_t: jnp.ndarray, qhi_t: jnp.ndarray,
                                    k: int, d: int) -> jnp.ndarray:
    """Weighted variant of :func:`stratified_moments_ref`: each sample's
    predicate contribution is scaled by ``w`` (S,) f32 (bootstrap resample
    weights; padding samples must carry ``w == 0``). Returns (Q, k, 3)
    [sum w*pred, sum w*pred*a, sum w*pred*a^2]."""
    S = a.shape[0]
    Q = qlo_t.shape[1]
    pred = jnp.ones((Q, S), dtype=jnp.bool_)
    for j in range(d):
        cj = c_t[j][None, :]
        pred = pred & (qlo_t[j][:, None] <= cj) & (cj <= qhi_t[j][:, None])
    pred = pred & (leaf >= 0)[None, :]
    predf = pred.astype(jnp.float32) * w[None, :]
    onehot = (leaf[:, None] == jnp.arange(k, dtype=jnp.int32)[None]
              ).astype(jnp.float32)              # (S,k)
    kp = predf @ onehot                          # (Q,k)
    sm = (predf * a[None]) @ onehot
    sq = (predf * (a * a)[None]) @ onehot
    return jnp.stack([kp, sm, sq], axis=-1)


def query_eval_ref(leaf_lo_t: jnp.ndarray, leaf_hi_t: jnp.ndarray,
                   leaf_agg: jnp.ndarray, qlo_t: jnp.ndarray,
                   qhi_t: jnp.ndarray, d: int
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Leaf classification + exact covered-aggregate accumulation.

    leaf_lo_t/leaf_hi_t (d_pad, k) transposed leaf boxes; leaf_agg (k, 8)
    padded aggregates [sum, sumsq, count, min, max, n_rows, 0, 0];
    qlo_t/qhi_t (d_pad, Q). Returns:
      rel     (Q, k) int32: 0 none / 1 partial / 2 cover,
      exact   (Q, 8) f32:  sum over covered leaves of leaf_agg.
    """
    Q = qlo_t.shape[1]
    k = leaf_lo_t.shape[1]
    nonempty = jnp.ones((k,), dtype=jnp.bool_)
    cover = jnp.ones((Q, k), dtype=jnp.bool_)
    disjoint = jnp.zeros((Q, k), dtype=jnp.bool_)
    for j in range(d):
        lo = leaf_lo_t[j][None, :]
        hi = leaf_hi_t[j][None, :]
        nonempty = nonempty & (leaf_lo_t[j] <= leaf_hi_t[j])
        cover = cover & (qlo_t[j][:, None] <= lo) & (hi <= qhi_t[j][:, None])
        disjoint = disjoint | (qhi_t[j][:, None] < lo) | (qlo_t[j][:, None] > hi)
    disjoint = disjoint | ~nonempty[None]
    cover = cover & nonempty[None]
    rel = jnp.where(cover, 2, jnp.where(disjoint, 0, 1)).astype(jnp.int32)
    exact = cover.astype(jnp.float32) @ leaf_agg
    return rel, exact


__all__ = ["segment_reduce_ref", "weighted_segment_reduce_ref",
           "stratified_moments_ref", "stratified_weighted_moments_ref",
           "query_eval_ref", "NEG_BIG", "POS_BIG"]
