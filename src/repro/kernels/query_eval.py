"""Pallas TPU kernel: vectorized MCF classification + exact partial aggregates.

The level-synchronous replacement for the paper's Algorithm 1 (DESIGN.md
§3): every (query, leaf) pair is classified cover/partial/none from the leaf
data bounding boxes, and the exact part of the answer is accumulated on the
MXU as ``cover_mask (BQ, BK) @ leaf_agg (BK, 8)``.

Grid: (q_tiles, k_tiles) with the leaf dimension innermost (sequential
accumulation of the exact part; the relation codes stream out per tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(lo_ref, hi_ref, agg_ref, qlo_ref, qhi_ref, rel_ref, exact_ref,
            *, d: int):
    kt = pl.program_id(1)
    bq = qlo_ref.shape[1]
    bk = lo_ref.shape[1]
    nonempty = jnp.ones((bk,), dtype=jnp.bool_)
    cover = jnp.ones((bq, bk), dtype=jnp.bool_)
    disjoint = jnp.zeros((bq, bk), dtype=jnp.bool_)
    for j in range(d):
        lo = lo_ref[j, :][None, :]
        hi = hi_ref[j, :][None, :]
        qlo = qlo_ref[j, :][:, None]
        qhi = qhi_ref[j, :][:, None]
        nonempty = nonempty & (lo_ref[j, :] <= hi_ref[j, :])
        cover = cover & (qlo <= lo) & (hi <= qhi)
        disjoint = disjoint | (qhi < lo) | (qlo > hi)
    disjoint = disjoint | ~nonempty[None, :]
    cover = cover & nonempty[None, :]
    rel_ref[...] = jnp.where(cover, 2, jnp.where(disjoint, 0, 1)
                             ).astype(jnp.int32)
    part = jax.lax.dot_general(cover.astype(jnp.float32), agg_ref[...],
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)

    @pl.when(kt == 0)
    def _init():
        exact_ref[...] = part

    @pl.when(kt != 0)
    def _acc():
        exact_ref[...] += part


@functools.partial(jax.jit, static_argnames=("d", "bq", "bk", "interpret"))
def query_eval(leaf_lo_t: jnp.ndarray, leaf_hi_t: jnp.ndarray,
               leaf_agg: jnp.ndarray, qlo_t: jnp.ndarray, qhi_t: jnp.ndarray,
               d: int, bq: int = 128, bk: int = 128,
               interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """leaf_lo_t/leaf_hi_t (d_pad, k); leaf_agg (k, 8); qlo_t/qhi_t (d_pad, Q).
    Q % bq == 0, k % bk == 0. Returns (rel (Q, k) int32, exact (Q, 8) f32)."""
    d_pad, k = leaf_lo_t.shape
    Q = qlo_t.shape[1]
    assert Q % bq == 0 and k % bk == 0, (Q, bq, k, bk)
    grid = (Q // bq, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d_pad, bk), lambda qt, kt: (0, kt)),
            pl.BlockSpec((d_pad, bk), lambda qt, kt: (0, kt)),
            pl.BlockSpec((bk, 8), lambda qt, kt: (kt, 0)),
            pl.BlockSpec((d_pad, bq), lambda qt, kt: (0, qt)),
            pl.BlockSpec((d_pad, bq), lambda qt, kt: (0, qt)),
        ],
        out_specs=[
            pl.BlockSpec((bq, bk), lambda qt, kt: (qt, kt)),
            pl.BlockSpec((bq, 8), lambda qt, kt: (qt, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
            jax.ShapeDtypeStruct((Q, 8), jnp.float32),
        ],
        interpret=interpret,
    )(leaf_lo_t, leaf_hi_t, leaf_agg, qlo_t, qhi_t)


__all__ = ["query_eval"]
