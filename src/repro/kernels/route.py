"""Tiled multi-D batch routing: nearest leaf box per row, O(tile) memory.

Streaming ingest routes every row of a batch to the leaf box that contains
it (distance 0) or is L1-nearest (``streaming/ingest.py``). The d > 1 path
used to materialize the dense (B, k) distance matrix and argmin it — fine
for small synopses, but the matrix is the single largest temporary of the
ingest step and grows with k. The formulations here stream leaf-box tiles
instead, keeping only an online (min-distance, argmin-leaf) pair per row:
same O(B·k) work, O(B·bk) live memory.

Tie semantics are bit-matched to the dense oracle: ``jnp.argmin`` takes
the *lowest* index among equal distances, reproduced by (a) per-tile
argmin (lowest index within the tile) and (b) a strict ``<`` merge across
tiles (an equal distance in a later tile never displaces the earlier
winner). Distances are accumulated per coordinate dimension in the same
order as the dense formulation, so the selected distance is bit-identical,
not just the leaf choice.

Padding strata (k padded to the tile multiple) are filled with inverted
±BIG boxes whose distance is ~BIG per dimension — unreachable, exactly
like the inverted empty-leaf boxes the build path stores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_BIG, POS_BIG

# Leaf-box tile of the streamed dimension (lane-aligned).
BOX_TILE = 128


def auto_block_k(k: int, tile: int = BOX_TILE) -> int:
    """Leaf-tile size for a k-leaf router call (``bk=None`` convention):
    the full lane tile, or k itself when the synopsis is smaller."""
    if k <= 0:
        return tile
    return min(tile, k)


def dist_matrix(lo, hi, c):
    """(B, K) L1 box distance, accumulated dimension-major exactly like
    the dense oracle (``max(lo - c, c - hi, 0)`` per dim, then add)."""
    d = c.shape[1]
    dist = None
    for j in range(d):
        lo_j = lo[:, j][None]                        # (1, K)
        hi_j = hi[:, j][None]
        cj = c[:, j][:, None]                        # (B, 1)
        dj = jnp.maximum(jnp.maximum(lo_j - cj, cj - hi_j), 0.0)
        dist = dj if dist is None else dist + dj
    return dist


def route_multid_dense(leaf_lo, leaf_hi, c):
    """Dense-oracle routing: materializes the (B, k) distance matrix.

    Returns (leaf ids (B,) int32, selected distance (B,) f32)."""
    dist = dist_matrix(leaf_lo, leaf_hi, c)
    leaf = jnp.argmin(dist, axis=1).astype(jnp.int32)
    dsel = jnp.take_along_axis(dist, leaf[:, None], axis=1)[:, 0]
    return leaf, dsel


def _pad_boxes(leaf_lo, leaf_hi, bk):
    k = leaf_lo.shape[0]
    pad = (-k) % bk
    if pad:
        d = leaf_lo.shape[1]
        leaf_lo = jnp.concatenate(
            [leaf_lo, jnp.full((pad, d), POS_BIG, leaf_lo.dtype)], axis=0)
        leaf_hi = jnp.concatenate(
            [leaf_hi, jnp.full((pad, d), NEG_BIG, leaf_hi.dtype)], axis=0)
    return leaf_lo, leaf_hi


@functools.partial(jax.jit, static_argnames=("bk",))
def route_multid_tiled(leaf_lo, leaf_hi, c, bk: int | None = None):
    """Streamed-jnp routing: ``lax.scan`` over (bk,)-leaf tiles carrying
    the per-row (best distance, best leaf) pair — never materializes more
    than a (B, bk) tile. Bit-matches :func:`route_multid_dense`."""
    bk = bk or auto_block_k(leaf_lo.shape[0])
    lo_p, hi_p = _pad_boxes(leaf_lo, leaf_hi, bk)
    k_pad = lo_p.shape[0]
    n_tiles = k_pad // bk
    b = c.shape[0]
    lo_tiles = lo_p.reshape(n_tiles, bk, -1)
    hi_tiles = hi_p.reshape(n_tiles, bk, -1)
    bases = (jnp.arange(n_tiles, dtype=jnp.int32) * bk)

    def step(carry, tile):
        best_d, best_i = carry
        lo_t, hi_t, base = tile
        dist = dist_matrix(lo_t, hi_t, c)                     # (B, bk)
        loc = jnp.min(dist, axis=1)
        arg = jnp.argmin(dist, axis=1).astype(jnp.int32) + base
        better = loc < best_d                                # strict: ties
        return (jnp.where(better, loc, best_d),              # keep earlier
                jnp.where(better, arg, best_i)), None

    init = (jnp.full((b,), jnp.inf, jnp.float32),
            jnp.zeros((b,), jnp.int32))
    (best_d, best_i), _ = jax.lax.scan(step, init,
                                       (lo_tiles, hi_tiles, bases))
    return best_i, best_d


def _route_kernel(lo_ref, hi_ref, c_ref, dist_ref, idx_ref, *, bk: int,
                  d: int):
    kt = pl.program_id(1)
    dist = None
    for j in range(d):
        lo_j = lo_ref[j, :][None, :]                       # (1, BK)
        hi_j = hi_ref[j, :][None, :]
        cj = c_ref[j, :][:, None]                          # (BB, 1)
        dj = jnp.maximum(jnp.maximum(lo_j - cj, cj - hi_j), 0.0)
        dist = dj if dist is None else dist + dj           # (BB, BK)
    loc = jnp.min(dist, axis=1)
    arg = jnp.argmin(dist, axis=1).astype(jnp.int32) + kt * bk

    @pl.when(kt == 0)
    def _init():
        dist_ref[...] = loc
        idx_ref[...] = arg

    @pl.when(kt != 0)
    def _merge():
        better = loc < dist_ref[...]                       # strict <: the
        idx_ref[...] = jnp.where(better, arg, idx_ref[...])  # earlier tile
        dist_ref[...] = jnp.where(better, loc, dist_ref[...])  # wins ties


@functools.partial(jax.jit, static_argnames=("d", "bb", "bk", "interpret"))
def route_multid_pallas(lo_t: jnp.ndarray, hi_t: jnp.ndarray,
                        c_t: jnp.ndarray, d: int, bb: int = 256,
                        bk: int = BOX_TILE, interpret: bool = True
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """lo_t/hi_t (d_pad, k_pad) transposed leaf boxes (padding strata at
    ±BIG inverted); c_t (d_pad, B_pad) transposed row coordinates.
    B_pad % bb == 0, k_pad % bk == 0. Returns (idx (B_pad,) int32,
    dist (B_pad,) f32) — the grid keeps the (min, argmin) running pair in
    the VMEM output block across the leaf-tile dimension, so no (B, k)
    buffer ever exists."""
    d_pad, k_pad = lo_t.shape
    B = c_t.shape[1]
    assert B % bb == 0 and k_pad % bk == 0, (B, bb, k_pad, bk)
    grid = (B // bb, k_pad // bk)
    dist, idx = pl.pallas_call(
        functools.partial(_route_kernel, bk=bk, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d_pad, bk), lambda bt, kt: (0, kt)),
            pl.BlockSpec((d_pad, bk), lambda bt, kt: (0, kt)),
            pl.BlockSpec((d_pad, bb), lambda bt, kt: (0, bt)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda bt, kt: (bt,)),
            pl.BlockSpec((bb,), lambda bt, kt: (bt,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B,), jnp.float32),
                   jax.ShapeDtypeStruct((B,), jnp.int32)],
        interpret=interpret,
    )(lo_t, hi_t, c_t)
    return idx, dist


__all__ = ["dist_matrix", "route_multid_dense", "route_multid_tiled", "route_multid_pallas",
           "auto_block_k", "BOX_TILE"]
