"""Pallas TPU kernels for the PASS hot paths + jnp references."""
from . import ops, ref  # noqa: F401
