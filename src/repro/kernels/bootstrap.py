"""Pallas TPU megakernel: all bootstrap replicate moments in one pass.

The uncertainty subsystem's Poisson bootstrap (DESIGN.md §7) needs, for
every replicate r, the weighted relevant-sample moments the
``stratified_weighted_moments`` kernel computes for one resample-weight
vector. The scan path dispatches that kernel once per replicate — R full
passes over the sample arrays. This megakernel instead revisits each
sample tile once per (replicate-tile, query-tile, stratum-tile) and emits
the whole (R, Q, k, 3) replicate-moment block from a single
``pallas_call``: the sample tile (coordinates, values, leaf ids) is loaded
into VMEM once per grid step and reused for all BR replicates of the
weight tile, so the data pass is amortized over the replicate block
instead of being repeated per replicate.

Bit-identity contract (DESIGN.md §10): the per-replicate arithmetic is an
*unrolled loop of exactly the 2-D matmuls the scan path's weighted kernel
performs* — same (BQ, BS) x (BS, BK) contraction shapes, same sample-tile
accumulation order (the s grid dimension stays innermost/sequential), so a
replicate's (Q, k, 3) slice is bit-identical to one
``stratified_weighted_moments`` call with the same weight row. Resample
weights are NOT generated in-kernel: they arrive as an (R, S) operand
drawn in one batched ``fold_in(key, r)`` threefry pass (see
``uncertainty/bootstrap.py``), which keeps the draws bit-matching the
sequential scan path on every jax version; the kernel streams them in
(BR, BS) tiles, so only one tile of the weight matrix is resident per
step.

Grid: (r_tiles, q_tiles, k_tiles, s_tiles) with the sample dimension
innermost (sequential accumulation into the (BR, BQ, BK, 3) output tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# Replicate-tile size: BR unrolled per-replicate matmul groups per grid
# step. 8 keeps the VMEM-resident predicate + one (BQ, BS) scratch per
# replicate small while amortizing the sample-tile load 8x.
REP_TILE = 8


def auto_block_r(r: int, tile: int = REP_TILE) -> int:
    """Replicate-block size for an R-replicate bootstrap: the full tile
    when R covers it, else R itself (small-R calls stay un-padded). The
    ``br=None`` convention mirrors ``segment_reduce.auto_block_n``."""
    if r <= 0:
        return tile
    return min(tile, r)


def _kernel(c_ref, a_ref, leaf_ref, w_ref, qlo_ref, qhi_ref, out_ref,
            *, br: int, bk: int, d: int):
    st = pl.program_id(3)
    kt = pl.program_id(2)
    a = a_ref[...]                        # (BS,)
    leaf = leaf_ref[...]                  # (BS,)
    bq = qlo_ref.shape[1]
    bs = a.shape[0]
    pred = jnp.ones((bq, bs), dtype=jnp.bool_)
    for j in range(d):
        cj = c_ref[j, :][None, :]                         # (1, BS)
        lo = qlo_ref[j, :][:, None]                       # (BQ, 1)
        hi = qhi_ref[j, :][:, None]
        pred = pred & (lo <= cj) & (cj <= hi)
    predb = pred.astype(jnp.float32)
    k_base = kt * bk
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (bs, bk), 1) + k_base
    onehot = (leaf[:, None] == k_iota).astype(jnp.float32)  # (BS, BK)

    def mm(lhs):   # (BQ, BS) @ (BS, BK) — the scan kernel's exact shape
        return jax.lax.dot_general(lhs, onehot, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    tiles = []
    for r in range(br):                   # unrolled replicate loop
        predf = predb * w_ref[r, :][None, :]
        kp = mm(predf)
        sm = mm(predf * a[None, :])
        sq = mm(predf * (a * a)[None, :])
        tiles.append(jnp.stack([kp, sm, sq], axis=-1))    # (BQ, BK, 3)
    tile = jnp.stack(tiles, axis=0)                       # (BR, BQ, BK, 3)

    @pl.when(st == 0)
    def _init():
        out_ref[...] = tile

    @pl.when(st != 0)
    def _acc():
        out_ref[...] += tile


@functools.partial(jax.jit,
                   static_argnames=("k", "d", "br", "bq", "bk", "bs",
                                    "interpret"))
def bootstrap_moments(c_t: jnp.ndarray, a: jnp.ndarray, leaf: jnp.ndarray,
                      w: jnp.ndarray, qlo_t: jnp.ndarray, qhi_t: jnp.ndarray,
                      k: int, d: int, br: int = REP_TILE, bq: int = 128,
                      bk: int = 128, bs: int = 1024,
                      interpret: bool = True) -> jnp.ndarray:
    """c_t (d_pad, S) f32; a (S,) f32; leaf (S,) int32 (-1 padding);
    w (R, S) f32 resample weights (padding samples carry w == 0);
    qlo_t/qhi_t (d_pad, Q). R % br == 0, S % bs == 0, Q % bq == 0,
    k % bk == 0. Returns (R, Q, k, 3) f32 =
    [sum w*pred, sum w*pred*a, sum w*pred*a^2] per replicate."""
    d_pad, S = c_t.shape
    R = w.shape[0]
    Q = qlo_t.shape[1]
    assert R % br == 0 and S % bs == 0 and Q % bq == 0 and k % bk == 0, \
        (R, br, S, bs, Q, bq, k, bk)
    grid = (R // br, Q // bq, k // bk, S // bs)
    return pl.pallas_call(
        functools.partial(_kernel, br=br, bk=bk, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d_pad, bs), lambda rt, qt, kt, st: (0, st)),
            pl.BlockSpec((bs,), lambda rt, qt, kt, st: (st,)),
            pl.BlockSpec((bs,), lambda rt, qt, kt, st: (st,)),
            pl.BlockSpec((br, bs), lambda rt, qt, kt, st: (rt, st)),
            pl.BlockSpec((d_pad, bq), lambda rt, qt, kt, st: (0, qt)),
            pl.BlockSpec((d_pad, bq), lambda rt, qt, kt, st: (0, qt)),
        ],
        out_specs=pl.BlockSpec((br, bq, bk, 3),
                               lambda rt, qt, kt, st: (rt, qt, kt, 0)),
        out_shape=jax.ShapeDtypeStruct((R, Q, k, 3), jnp.float32),
        interpret=interpret,
    )(c_t, a, leaf, w, qlo_t, qhi_t)


__all__ = ["bootstrap_moments", "auto_block_r", "REP_TILE"]
