"""Quickstart: build a PASS synopsis once, serve many queries through the
`PassEngine` facade.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import PassEngine, ServingConfig
from repro.core import (build_synopsis, ground_truth, random_queries,
                        relative_error, ci_ratio)
from repro.data import synthetic


def main():
    # ~380k taxi-like rows: predicate = pickup time, aggregate = distance.
    c, a = synthetic.nyc_taxi(scale=0.05)
    print(f"dataset: {len(a):,} rows")

    # Budgets (paper §3.1): k leaf partitions (construction budget tau_c),
    # 0.5% stratified samples (query-latency budget tau_q).
    syn, report = build_synopsis(c, a, k=64, sample_rate=0.005,
                                 kind="sum", method="adp")
    print(f"built PASS synopsis in {report.seconds_total:.2f}s "
          f"(k={report.k}, samples={report.total_samples})")

    # Configure once, serve many: every kind below comes from ONE shared
    # classification + moment pass per batch.
    kinds = ("sum", "count", "avg", "min", "max")
    eng = PassEngine(syn, serving=ServingConfig(kinds=kinds))

    queries = random_queries(c, 500, seed=0)
    res = eng.answer(queries)
    for kind in kinds:
        gt = ground_truth(c, a, queries, kind=kind)
        keep = np.abs(gt) > 1e-9
        err = np.median(relative_error(res[kind], gt)[keep])
        print(f"{kind:6s} median rel err {err*100:6.3f}%", end="")
        if kind in ("sum", "count", "avg"):
            ci = np.median(ci_ratio(res[kind], gt)[keep])
            inside = np.mean((np.asarray(res[kind].lower) <= gt)
                             & (gt <= np.asarray(res[kind].upper)))
            print(f"   CI ratio {ci*100:5.2f}%   hard-bound containment "
                  f"{inside*100:.1f}%")
        else:
            print()

    # Steady-state serving: pin the batch shape once, then every call
    # skips the per-call Python re-setup entirely.
    prepared = eng.prepare(queries)
    prepared(queries)                      # second call AOT-compiles
    again = prepared(random_queries(c, 500, seed=1))
    print(f"prepared handle answered {len(np.asarray(again['sum'].estimate))} "
          f"queries; engine stats: {eng.stats()}")


if __name__ == "__main__":
    main()
