"""Quickstart: build a PASS synopsis and answer approximate queries.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (build_synopsis, answer, ground_truth, random_queries,
                        relative_error, ci_ratio)
from repro.data import synthetic


def main():
    # ~380k taxi-like rows: predicate = pickup time, aggregate = distance.
    c, a = synthetic.nyc_taxi(scale=0.05)
    print(f"dataset: {len(a):,} rows")

    # Budgets (paper §3.1): k leaf partitions (construction budget tau_c),
    # 0.5% stratified samples (query-latency budget tau_q).
    syn, report = build_synopsis(c, a, k=64, sample_rate=0.005,
                                 kind="sum", method="adp")
    print(f"built PASS synopsis in {report.seconds_total:.2f}s "
          f"(k={report.k}, samples={report.total_samples})")

    queries = random_queries(c, 500, seed=0)
    for kind in ("sum", "count", "avg", "min", "max"):
        res = answer(syn, queries, kind=kind)
        gt = ground_truth(c, a, queries, kind=kind)
        keep = np.abs(gt) > 1e-9
        err = np.median(relative_error(res, gt)[keep])
        print(f"{kind:6s} median rel err {err*100:6.3f}%", end="")
        if kind in ("sum", "count", "avg"):
            ci = np.median(ci_ratio(res, gt)[keep])
            inside = np.mean((np.asarray(res.lower) <= gt)
                             & (gt <= np.asarray(res.upper)))
            print(f"   CI ratio {ci*100:5.2f}%   hard-bound containment "
                  f"{inside*100:.1f}%")
        else:
            print()


if __name__ == "__main__":
    main()
