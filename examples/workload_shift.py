"""Workload shift (paper §5.4.1): a KD-PASS synopsis built for a 2-D query
template keeps helping when the workload drifts to 1-D/3-D/4-D templates
that share attributes — data skipping stays aggressive and reliable.

    PYTHONPATH=src python examples/workload_shift.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (build_synopsis, answer, ground_truth, random_queries,
                        relative_error)
from repro.core.estimators import skip_rate
from repro.core.types import QueryBatch
from repro.data import synthetic


def main():
    c, a = synthetic.nyc_taxi(scale=0.01, dims=4)
    print(f"dataset: {len(a):,} rows x 4 predicate columns")
    # Synopsis optimized for the 2-D template (pickup time x dropoff time).
    syn, rep = build_synopsis(c[:, :2], a, k=128, sample_rate=0.01,
                              kind="sum", method="kd")
    print(f"KD-PASS built for the 2-D template in {rep.seconds_total:.2f}s")

    for t in (1, 2, 3, 4):
        qs_t = random_queries(c[:, :t], 200, seed=42 + t,
                              min_frac=0.1, max_frac=0.5)
        shared = min(t, 2)
        lo = np.full((200, 2), -np.inf, np.float32)
        hi = np.full((200, 2), np.inf, np.float32)
        lo[:, :shared] = np.asarray(qs_t.lo)[:, :shared]
        hi[:, :shared] = np.asarray(qs_t.hi)[:, :shared]
        qs2 = QueryBatch(jnp.asarray(lo), jnp.asarray(hi))
        res = answer(syn, qs2, kind="sum")
        gt = ground_truth(c[:, :2], a, qs2, kind="sum")
        keep = np.abs(gt) > 1e-9
        err = np.median(relative_error(res, gt)[keep])
        sr = float(np.median(np.asarray(skip_rate(syn, qs2))))
        print(f"Q{t} template ({shared} shared attrs): median rel err "
              f"{err*100:6.3f}%   skip rate {sr*100:5.1f}%")


if __name__ == "__main__":
    main()
