"""Workload shift (paper §5.4.1) and data shift (§4.5): a KD-PASS synopsis
built for a 2-D query template keeps helping when the workload drifts to
1-D/3-D/4-D templates that share attributes — and when the *data* drifts,
the streaming subsystem keeps serving fresh answers via batched ingest +
delta-merge, re-optimizing the partition on device once the drift policy
trips.

    PYTHONPATH=src python examples/workload_shift.py
"""
import numpy as np
import jax.numpy as jnp

from repro.api import PassEngine, ServingConfig
from repro.core import (build_synopsis, ground_truth, random_queries,
                        relative_error)
from repro.core.estimators import skip_rate
from repro.core.types import QueryBatch
from repro.data import synthetic
from repro.streaming import StreamingIngestor, DriftPolicy


def main():
    c, a = synthetic.nyc_taxi(scale=0.01, dims=4)
    print(f"dataset: {len(a):,} rows x 4 predicate columns")
    # Synopsis optimized for the 2-D template (pickup time x dropoff time).
    syn, rep = build_synopsis(c[:, :2], a, k=128, sample_rate=0.01,
                              kind="sum", method="kd")
    print(f"KD-PASS built for the 2-D template in {rep.seconds_total:.2f}s")

    eng = PassEngine(syn, serving=ServingConfig(kinds=("sum",)))
    for t in (1, 2, 3, 4):
        qs_t = random_queries(c[:, :t], 200, seed=42 + t,
                              min_frac=0.1, max_frac=0.5)
        shared = min(t, 2)
        lo = np.full((200, 2), -np.inf, np.float32)
        hi = np.full((200, 2), np.inf, np.float32)
        lo[:, :shared] = np.asarray(qs_t.lo)[:, :shared]
        hi[:, :shared] = np.asarray(qs_t.hi)[:, :shared]
        qs2 = QueryBatch(jnp.asarray(lo), jnp.asarray(hi))
        res = eng.answer(qs2)["sum"]
        gt = ground_truth(c[:, :2], a, qs2, kind="sum")
        keep = np.abs(gt) > 1e-9
        err = np.median(relative_error(res, gt)[keep])
        sr = float(np.median(np.asarray(skip_rate(syn, qs2))))
        print(f"Q{t} template ({shared} shared attrs): median rel err "
              f"{err*100:6.3f}%   skip rate {sr*100:5.1f}%")

    streaming_demo()


def streaming_demo():
    """Continuous ingest + delta-merge serving + drift-triggered reopt."""
    print("\n-- data shift: continuous ingest (streaming subsystem) --")
    c4, a = synthetic.nyc_taxi(scale=0.01, dims=1)
    c = np.asarray(c4).reshape(-1)
    a = np.asarray(a)
    syn, _ = build_synopsis(c, a, k=64, sample_rate=0.02, kind="sum")
    rng = np.random.default_rng(7)
    n_new = len(a) // 2
    c_new = rng.uniform(c.max(), c.max() * 1.5, n_new)  # new territory
    a_new = rng.lognormal(1.5, 1.0, n_new)

    ing = StreamingIngestor(syn, seed=1)
    batch = 2048
    for i in range(0, n_new - batch + 1, batch):
        ing.ingest(c_new[i:i + batch], a_new[i:i + batch])
    streamed = (n_new // batch) * batch
    print(f"streamed {streamed:,} rows in {streamed // batch} vectorized "
          f"batches; staleness {ing.staleness():.2f}, "
          f"out-of-box {ing.oob_frac():.2f}")

    c_all = np.concatenate([c, c_new[:streamed]])
    a_all = np.concatenate([a, a_new[:streamed]])
    qs = random_queries(c_all, 200, seed=9, min_frac=0.05, max_frac=0.4)
    gt = ground_truth(c_all, a_all, qs, kind="sum")
    keep = np.abs(gt) > 1e-9
    drift_q = (np.asarray(qs.hi).reshape(-1) > c.max())[keep]

    def med(src, label):
        res = PassEngine(src).answer(qs)["sum"]
        rel = relative_error(res, gt)[keep]
        print(f"  {label:34s} median rel err {np.median(rel)*100:6.3f}% "
              f"(drift-touching queries {np.median(rel[drift_q])*100:6.3f}%)")

    med(syn, "frozen base (stale)")
    # One engine serves the live stream; replace_source() swaps in the
    # re-optimized ingestor and invalidates every prepared plan.
    live = PassEngine(ing)
    rel = relative_error(live.answer(qs)["sum"], gt)[keep]
    print(f"  {'delta-merged stream':34s} median rel err "
          f"{np.median(rel)*100:6.3f}% "
          f"(drift-touching queries {np.median(rel[drift_q])*100:6.3f}%)")
    pol = DriftPolicy(staleness_threshold=0.2)
    ing2, report = pol.maybe_reoptimize(ing, c_all, a_all)
    assert report is not None
    live.replace_source(ing2)
    rel = relative_error(live.answer(qs)["sum"], gt)[keep]
    print(f"  {'re-optimized (dp_monotone_jnp)':34s} median rel err "
          f"{np.median(rel)*100:6.3f}% "
          f"(drift-touching queries {np.median(rel[drift_q])*100:6.3f}%)")


if __name__ == "__main__":
    main()
