"""Multi-tenant AQP service: concurrent tenants against one PassEngine
through the request coalescer (DESIGN.md §12).

The end-to-end *serving many tenants* demo: a synopsis is built offline,
a :class:`RequestCoalescer` + :class:`TickDriver` front it, and N tenant
threads fire small ragged query batches concurrently. The coalescer
packs each tick's queue into padded shape-class batches — one device
dispatch per class — and demuxes bit-identical per-tenant results back
through futures. Shed requests (admission control) are retried with
backoff, the way a real client would.

Artifacts land in a run directory (``--out``): ``stats.json`` with the
coalescer + engine + per-tenant accounting snapshot, and a printed
summary of dispatch amortization and queue-wait percentiles.

    PYTHONPATH=src python examples/serve_service.py [--tenants 8]
    PYTHONPATH=src python examples/serve_service.py --ci 0.95 --seconds 3
"""
import argparse
import json
import pathlib
import threading
import time

import numpy as np

from repro.api import PassEngine, ServingConfig, CIConfig, CoalescerConfig
from repro.core import build_synopsis, random_queries
from repro.data import synthetic
from repro.serve import RequestCoalescer, TickDriver, Overloaded


def tenant_loop(name, co, c, stop, out, seed, batch_lo=3, batch_hi=18):
    """One tenant: ragged submissions, retry-with-backoff on shed."""
    rng = np.random.default_rng(seed)
    served = shed = 0
    while not stop.is_set():
        qs = random_queries(c, int(rng.integers(batch_lo, batch_hi)),
                            seed=int(rng.integers(1 << 31)))
        try:
            res = co.answer(name, qs, timeout=30.0)
            assert set(res) == set(co.engine.serving.kinds)
            served += 1
        except Overloaded:
            shed += 1
            time.sleep(0.002 * (1 + rng.random()))   # jittered backoff
    out[name] = {"served_requests": served, "shed_retries": shed}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--seconds", type=float, default=2.0)
    ap.add_argument("--k", type=int, default=128)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--kinds", type=str, default="sum,count,avg")
    ap.add_argument("--ci", type=float, default=None,
                    help="confidence level (e.g. 0.95) — served per tick "
                         "through the same coalesced dispatches")
    ap.add_argument("--tick-ms", type=float, default=2.0)
    ap.add_argument("--shape-classes", type=str, default="8,32,128")
    ap.add_argument("--out", type=str, default="runs/serve_service")
    args = ap.parse_args()

    c, a = synthetic.nyc_taxi(scale=args.scale)
    syn, rep = build_synopsis(c, a, k=args.k, sample_rate=0.01, kind="sum")
    print(f"[serve] synopsis ready ({rep.seconds_total:.2f}s build, "
          f"k={rep.k}, {rep.total_samples} samples)")

    eng = PassEngine(
        syn,
        serving=ServingConfig(kinds=tuple(args.kinds.split(","))),
        ci=CIConfig(level=args.ci) if args.ci else None)
    co = RequestCoalescer(eng, CoalescerConfig(
        tick_ms=args.tick_ms,
        shape_classes=tuple(int(s) for s in args.shape_classes.split(",")),
        max_outstanding=4, max_queue_depth=16 * args.tenants))

    # Warm the per-class prepared executables (jit on 1st call, AOT on
    # 2nd) so tenant latencies below measure serving, not compilation.
    for b in co.config.shape_classes:
        warm = random_queries(c, b, seed=7)
        prepared = eng.prepare((b, syn.d))
        prepared(warm)
        prepared(warm)
    print(f"[serve] warmed shape classes {co.config.shape_classes}")

    stop = threading.Event()
    tenant_stats: dict = {}
    threads = [threading.Thread(
        target=tenant_loop, name=f"tenant-{i}",
        args=(f"tenant-{i}", co, c, stop, tenant_stats, 1000 + i),
        daemon=True) for i in range(args.tenants)]
    with TickDriver(co):
        for t in threads:
            t.start()
        time.sleep(args.seconds)
        stop.set()
        for t in threads:
            t.join(timeout=60.0)
        # driver exit flushes anything still queued

    s = co.stats()
    waits = [t["wait_p95_ms"] for t in s["tenants"].values()]
    print(f"[serve] {args.tenants} tenants for {args.seconds:.1f}s: "
          f"{s['served']} requests served, {s['shed']} shed, "
          f"{s['dispatches']} device dispatches over {s['ticks']} ticks")
    if s["dispatches"]:
        print(f"[serve] amortization {s['coalesced_rows'] / s['dispatches']:.1f} "
              f"rows/dispatch (pad overhead "
              f"{s['padded_rows'] / max(s['coalesced_rows'], 1):.2f}), "
              f"queue-wait p95 {max(waits):.2f} ms worst tenant")
    run_dir = pathlib.Path(args.out)
    run_dir.mkdir(parents=True, exist_ok=True)
    payload = {
        "config": {"tenants": args.tenants, "seconds": args.seconds,
                   "k": args.k, "kinds": args.kinds, "ci": args.ci,
                   "tick_ms": args.tick_ms,
                   "shape_classes": args.shape_classes},
        "coalescer": s,
        "engine": {k: v for k, v in eng.stats().items()
                   if k != "coalescer"},
        "tenant_clients": tenant_stats,
    }
    path = run_dir / "stats.json"
    path.write_text(json.dumps(payload, indent=2, sort_keys=True,
                               default=str))
    print(f"[serve] wrote {path}")


if __name__ == "__main__":
    main()
