"""End-to-end training driver (deliverable b): train a ~100M-param decoder
for a few hundred steps with checkpointing + PASS-backed telemetry.

The PASS synopsis answers mixture/telemetry queries over the training
stream (per-domain mean loss over step ranges) without scanning history —
the paper's technique as the analytics layer of the pipeline (DESIGN.md §5).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim import AdamWConfig, init_opt_state
from repro.checkpoint.checkpoint import CheckpointManager
from repro.runtime.fault_tolerance import StragglerMonitor
from repro.data.loader import TokenLoader
from repro.core import build_synopsis, answer
from repro.core.types import QueryBatch


def small_lm() -> ModelConfig:
    """~100M params: 8 layers x 512 d_model, 32k vocab."""
    return ModelConfig(
        name="demo-100m", family="dense", num_layers=8, d_model=512,
        num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32768, dtype="float32", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = small_lm()
    opt_cfg = AdamWConfig(lr=6e-4, total_steps=args.steps, warmup_steps=30)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(np.prod(x.shape) for x in jax.tree.leaves(params))
    print(f"[train_lm] {cfg.name}: {n_params/1e6:.1f}M params")
    opt = init_opt_state(params)
    loader = TokenLoader(cfg.vocab_size, args.seq, args.batch)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    mon = StragglerMonitor()
    step_fn = jax.jit(lambda p, o, b: M.train_step(p, o, b, cfg, opt_cfg),
                      donate_argnums=(0, 1))

    losses = []
    for step in range(args.steps):
        t0 = time.perf_counter()
        raw = loader.next_batch()
        batch = {"tokens": jnp.asarray(raw["tokens"]),
                 "labels": jnp.asarray(raw["labels"])}
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        # per-domain telemetry into the PASS table
        dom_loss = loss + 0.1 * np.sin(raw["domains"][:loader.num_domains])
        loader.record_telemetry(step, dom_loss)
        mon.observe(time.perf_counter() - t0)
        if step % 50 == 0 or step == args.steps - 1:
            print(f"[train_lm] step {step:4d} loss {loss:.4f}")
        if (step + 1) % 100 == 0:
            mgr.save(step + 1, (params, opt, loader.snapshot()))
    mgr.wait()
    print(f"[train_lm] loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({args.steps} steps)")

    # ---- PASS over training telemetry: mean loss per step-range ----
    c, a = loader.telemetry_table()
    syn, rep = build_synopsis(c, a, k=16, sample_rate=0.25, method="eq")
    thirds = np.linspace(0, c.max(), 4)
    qlo = thirds[:-1][:, None].astype(np.float32)
    qhi = thirds[1:][:, None].astype(np.float32)
    res = answer(syn, QueryBatch(jnp.asarray(qlo), jnp.asarray(qhi)),
                 kind="avg")
    print("[train_lm] PASS telemetry — mean loss by training phase:",
          [f"{float(x):.3f}" for x in res.estimate])
    if args.steps >= 50:   # too few steps never clear the warmup
        assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
