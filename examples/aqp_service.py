"""AQP serving: batched approximate queries against a PASS synopsis through
the layered engine (plan/execute/assemble), with the distributed shard_map
paths when multiple devices exist.

This is the end-to-end *serve* driver (deliverable b): a synopsis is built
offline, then a stream of query batches is answered with latency stats,
hard bounds, and ESS/skip-rate accounting — the paper's full query
processing pipeline (§3.3). Each request asks for several aggregate kinds
at once (`--kinds sum,count,avg`); the engine answers all of them from one
shared classification + moment pass per batch.

    PYTHONPATH=src python examples/aqp_service.py [--batches 20]
    # multi-device serving demo:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/aqp_service.py --distributed
"""
import argparse
import time

import numpy as np
import jax

from repro.api import PassEngine, ServingConfig
from repro.core import build_synopsis, ground_truth, random_queries
from repro.core.estimators import ess, skip_rate
from repro.core import distributed as dist
from repro.data import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--kinds", type=str, default="sum,count,avg",
                    help="comma-separated aggregate kinds per request")
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args()
    kinds = tuple(args.kinds.split(","))

    c, a = synthetic.nyc_taxi(scale=0.05)
    syn, rep = build_synopsis(c, a, k=128, sample_rate=0.01, kind="sum")
    print(f"[service] synopsis ready ({rep.seconds_total:.2f}s build, "
          f"k={rep.k}, {rep.total_samples} samples, "
          f"{syn.storage_floats()*4/2**20:.2f} MiB)")

    mesh = None
    if args.distributed and len(jax.devices()) > 1:
        n = len(jax.devices())
        mesh = jax.make_mesh((n,), ("data",))
        print(f"[service] distributed mode over {n} devices")
        if kinds != ("sum",):
            print("[service] note: the sharded serving path answers SUM "
                  f"only; ignoring --kinds {args.kinds}")
            kinds = ("sum",)

    # Configure once, serve many: the engine pins a prepared plan per batch
    # shape, so the steady-state loop below never re-does Python-side setup.
    eng = PassEngine(syn, serving=ServingConfig(kinds=kinds))
    prepared = eng.prepare((args.batch_size, syn.d))
    warm = random_queries(c, args.batch_size, seed=99)
    jax.block_until_ready(prepared(warm))       # jit compile
    jax.block_until_ready(prepared(warm))       # AOT-compile the entry

    lat, errs = [], {kd: [] for kd in kinds}
    for b in range(args.batches):
        qs = random_queries(c, args.batch_size, seed=100 + b)
        t0 = time.perf_counter()
        if mesh is not None:
            est, ci, lo, hi = dist.serve_queries_sharded(mesh, syn, qs,
                                                         kind="sum")
            est.block_until_ready()
            res = {"sum": np.asarray(est)}
        else:
            out = prepared(qs)
            jax.block_until_ready(out)
            res = {kd: np.asarray(out[kd].estimate) for kd in kinds}
        dt = time.perf_counter() - t0
        lat.append(dt)
        for kd, est in res.items():
            gt = ground_truth(c, a, qs, kind=kd)
            keep = np.abs(gt) > 1e-9
            errs[kd].append(np.median(np.abs(est - gt)[keep]
                                      / np.abs(gt)[keep]))
    qs = random_queries(c, args.batch_size, seed=0)
    e = np.asarray(ess(syn, qs))
    s = np.asarray(skip_rate(syn, qs))
    served = len(kinds) if mesh is None else 1
    print(f"[service] {args.batches} batches x {args.batch_size} queries "
          f"x {served} aggregate kind(s)/request")
    print(f"[service] median latency/batch {np.median(lat)*1000:.2f} ms "
          f"({np.median(lat)/args.batch_size*1e6:.1f} us/query, steady-state;"
          " one classification + one moment pass per batch)")
    for kd, ee in errs.items():
        if ee:
            print(f"[service] median rel err [{kd}] {np.median(ee)*100:.3f}%")
    print(f"[service] mean ESS {e.mean():.1f} samples/query, "
          f"mean skip rate {s.mean()*100:.1f}%")


if __name__ == "__main__":
    main()
